"""Continuous-batching scheduler: packing, deadlines, hot-swap, metrics.

Everything here runs on a VIRTUAL clock (the scheduler's injectable
``clock=``), so queueing behavior is deterministic; the wall-clock load
run lives in the ``load``-marked test at the bottom (CI slow job).
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dual import task_scores
from repro.serve import (
    ContinuousBatchingScheduler,
    LatencyHistogram,
    ModelSnapshot,
    MTLScoringEngine,
    QueueFull,
    ScoreRequest,
    ServingMetrics,
    VirtualClock as ManualClock,
)


class PacedEngine:
    """Adapter wrapper: each tile advances the virtual clock by a scripted
    service time (straggler tiles included) before scoring; everything but
    ``run_tile`` delegates to the wrapped engine."""

    def __init__(self, inner, clock, service_s):
        self.inner, self.clock = inner, clock
        self.service_s = list(service_s)
        self.tiles = 0

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def run_tile(self, reqs, snapshot):
        dt = self.service_s[min(self.tiles, len(self.service_s) - 1)]
        self.tiles += 1
        self.clock.advance(dt)
        self.inner.run_tile(reqs, snapshot)


@pytest.fixture()
def W():
    return np.random.RandomState(0).randn(5, 12).astype(np.float32)


def _requests(n, m=5, d=12, seed=1):
    rng = np.random.RandomState(seed)
    return [
        ScoreRequest(task=int(rng.randint(m)), x=rng.randn(d).astype(np.float32))
        for _ in range(n)
    ]


def test_partial_tiles_pack_immediately(W):
    """Arrivals smaller than a batch still get served (padded tile) —
    continuous batching, not blocking-until-full."""
    clk = ManualClock()
    sched = ContinuousBatchingScheduler(
        MTLScoringEngine(W, batch=4), clock=clk
    )
    reqs = _requests(3)
    sched.submit_many(reqs)
    done = sched.step()
    assert [r is d for r, d in zip(reqs, done)] == [True] * 3
    assert all(r.status == "done" and r.score is not None for r in reqs)
    assert sched.metrics.tiles == 1 and sched.metrics.tile_fill() == 0.75
    assert sched.step() == []  # idle


def test_fifo_vs_edf_packing(W):
    clk = ManualClock()
    eng = MTLScoringEngine(W, batch=2)
    sched = ContinuousBatchingScheduler(eng, policy="edf", clock=clk)
    a, b, c = _requests(3)
    sched.submit(a, deadline_s=10.0)
    sched.submit(b)  # no deadline -> packs last under EDF
    sched.submit(c, deadline_s=1.0)
    tile = sched.step()
    assert tile == [c, a]  # earliest deadline first
    assert sched.step() == [b]

    sched2 = ContinuousBatchingScheduler(
        MTLScoringEngine(W, batch=2), policy="fifo", clock=ManualClock()
    )
    a2, b2, c2 = _requests(3)
    sched2.submit(a2, deadline_s=10.0)
    sched2.submit(b2)
    sched2.submit(c2, deadline_s=1.0)
    assert sched2.step() == [a2, b2]  # arrival order


def test_deadline_aware_admission_and_expiry(W):
    clk = ManualClock()
    sched = ContinuousBatchingScheduler(
        MTLScoringEngine(W, batch=4), clock=clk
    )
    # expired at the door: absolute deadline already in the past
    dead = _requests(1)[0]
    dead.deadline_s = -1.0
    sched.submit(dead)
    assert dead.status == "expired" and sched.pending == 0
    # expired at packing: deadline passes while queued
    late, ok = _requests(2)
    sched.submit(late, deadline_s=0.5)
    sched.submit(ok, deadline_s=100.0)
    clk.advance(1.0)
    tile = sched.step()
    assert late.status == "expired" and late.score is None
    assert tile == [ok] and ok.status == "done"
    m = sched.metrics
    assert m.expired == 2 and m.slo_violations == 2 and m.completed == 1


def test_slo_violation_accounting(W):
    clk = ManualClock()
    eng = PacedEngine(MTLScoringEngine(W, batch=4), clk, [0.2])
    sched = ContinuousBatchingScheduler(eng, slo_s=0.1, clock=clk)
    sched.submit_many(_requests(2))
    sched.step()  # service 0.2s > slo 0.1s
    assert sched.metrics.slo_violations == 2
    assert sched.metrics.latency.percentile(50) == pytest.approx(0.2)


def test_bounded_queue_rejects(W):
    sched = ContinuousBatchingScheduler(
        MTLScoringEngine(W, batch=2), max_queue=2, clock=ManualClock()
    )
    r1, r2, r3 = _requests(3)
    sched.submit(r1)
    sched.submit(r2)
    with pytest.raises(QueueFull):
        sched.submit(r3)
    assert sched.metrics.rejected == 1 and sched.pending == 2


def test_admission_validates_once(W):
    sched = ContinuousBatchingScheduler(
        MTLScoringEngine(W, batch=2), clock=ManualClock()
    )
    with pytest.raises(ValueError, match="task id"):
        sched.submit(ScoreRequest(task=9, x=np.zeros(12, np.float32)))
    with pytest.raises(ValueError, match="feature shape"):
        sched.submit(ScoreRequest(task=0, x=np.zeros(3, np.float32)))
    assert sched.pending == 0


# ---------------------------------------------------------------------------
# hot-swap
# ---------------------------------------------------------------------------
def test_hot_swap_bit_equal_no_drops(W):
    """Scores before/after a snapshot switch are BIT-equal to direct
    task_scores against the respective W version; every request is scored
    exactly once."""
    rng = np.random.RandomState(3)
    W2 = rng.randn(*W.shape).astype(np.float32)
    clk = ManualClock()
    eng = MTLScoringEngine(W, batch=4, version=1)
    sched = ContinuousBatchingScheduler(eng, clock=clk)
    reqs = _requests(10, seed=4)
    sched.submit_many(reqs)
    done = list(sched.step())  # one tile on v1
    sched.publish(ModelSnapshot(version=2, W=W2))
    while sched.pending:
        done += sched.step()
    # no dropped or double-scored requests
    assert len(done) == len(reqs) and len({id(r) for r in done}) == len(reqs)
    assert sorted({r.snapshot_version for r in done}) == [1, 2]
    step = jax.jit(task_scores)
    for version, Wv in ((1, W), (2, W2)):
        group = [r for r in done if r.snapshot_version == version]
        assert group, f"no requests served on version {version}"
        X = np.stack([r.x for r in group])
        t = np.asarray([r.task for r in group], np.int32)
        # pad to the tile shape so the comparison runs the exact executable
        pad = (-len(group)) % eng.batch
        Xp = np.concatenate([X, np.zeros((pad, X.shape[1]), np.float32)])
        tp = np.concatenate([t, np.zeros((pad,), np.int32)])
        ref = np.asarray(step(jnp.asarray(Wv), jnp.asarray(Xp), jnp.asarray(tp)))
        got = np.asarray([r.score for r in group], np.float32)
        np.testing.assert_array_equal(got, ref[: len(group)])


def test_in_flight_tile_completes_on_packed_snapshot(W):
    """A publish landing mid-tile must NOT leak into that tile."""
    W2 = np.random.RandomState(5).randn(*W.shape).astype(np.float32)
    clk = ManualClock()
    eng = MTLScoringEngine(W, batch=4, version=1)
    sched = ContinuousBatchingScheduler(eng, clock=clk)

    inner_run_tile = eng.run_tile

    def swapping_run_tile(reqs, snapshot):
        # simulate a training thread publishing while the tile executes
        sched.publish(ModelSnapshot(version=2, W=W2))
        inner_run_tile(reqs, snapshot)

    eng.run_tile = swapping_run_tile
    reqs = _requests(2, seed=6)
    sched.submit_many(reqs)
    (r0, r1) = sched.step()
    assert r0.snapshot_version == 1 and r1.snapshot_version == 1
    assert r0.score == pytest.approx(float(r0.x @ W[r0.task]), abs=1e-5)
    eng.run_tile = inner_run_tile
    more = _requests(1, seed=7)
    sched.submit_many(more)
    assert sched.step()[0].snapshot_version == 2


def test_publish_version_must_increase(W):
    eng = MTLScoringEngine(W, batch=2, version=3)
    sched = ContinuousBatchingScheduler(eng, clock=ManualClock())
    # equal version = duplicate delivery: idempotent no-op, not a swap
    assert sched.publish(ModelSnapshot(version=3, W=W)) == 3
    assert sched.metrics.swaps == 0
    with pytest.raises(ValueError, match="not newer"):
        sched.publish(ModelSnapshot(version=2, W=W))
    with pytest.raises(TypeError):
        sched.publish(W)
    with pytest.raises(ValueError, match="shape"):
        eng.publish(ModelSnapshot(version=9, W=np.zeros((2, 2), np.float32)))
    assert sched.publish_weights(W) == 4  # auto-increment
    # an external version counter BEHIND the scheduler's is re-stamped
    # into its monotone version space, never dropped (transport counters
    # and estimator versions are independent sequences)
    assert sched.publish_weights(W, version=1) == 5
    # the scheduler shape-checks published snapshots against the engine
    with pytest.raises(ValueError, match="shape"):
        sched.publish(ModelSnapshot(version=9, W=np.zeros((2, 2), np.float32)))
    with pytest.raises(ValueError, match="shape"):
        sched.publish_weights(np.zeros((2, 2), np.float32))
    assert sched.version == 5  # nothing installed by the rejected pushes


def test_scheduler_picks_up_engine_pushed_snapshot(W):
    """A scheduler composed directly over an engine must notice snapshots
    pushed INTO the engine (e.g. by an estimator) at pack time."""
    W2 = np.random.RandomState(11).randn(*W.shape).astype(np.float32)
    eng = MTLScoringEngine(W, batch=4, version=1)
    sched = ContinuousBatchingScheduler(eng, clock=ManualClock())
    eng.swap(W2)  # push lands on the engine, not the scheduler
    (out,) = sched.submit_many(_requests(1, seed=12))
    r = out.request
    assert out.admitted
    sched.step()
    assert r.snapshot_version == 2 and sched.version == 2
    assert r.score == pytest.approx(float(r.x @ W2[r.task]), abs=1e-5)
    assert sched.metrics.swaps == 1


def test_engine_push_survives_scheduler_counter_running_ahead(W):
    """Pickup is by snapshot IDENTITY: an engine-side push whose version
    number is BEHIND a scheduler counter that other producers restamped
    ahead must still install (restamped), not be silently ignored."""
    W2 = np.random.RandomState(14).randn(*W.shape).astype(np.float32)
    W3 = np.random.RandomState(15).randn(*W.shape).astype(np.float32)
    eng = MTLScoringEngine(W, batch=4, version=1)
    sched = ContinuousBatchingScheduler(eng, clock=ManualClock())
    # e.g. a transport subscription pushes the scheduler counter to 6
    for _ in range(5):
        sched.publish_weights(W2)
    assert sched.version == 6 and eng.version == 1
    eng.swap(W3)  # engine-side push: version 2, numerically behind 6
    (out,) = sched.submit_many(_requests(1, seed=16))
    r = out.request
    sched.step()
    assert r.snapshot_version == 7  # restamped into the scheduler space
    assert r.score == pytest.approx(float(r.x @ W3[r.task]), abs=1e-5)


def test_failed_tile_requeues_requests(W):
    eng = MTLScoringEngine(W, batch=4)
    sched = ContinuousBatchingScheduler(eng, clock=ManualClock())
    reqs = [o.request for o in sched.submit_many(_requests(3, seed=13))]

    def boom(tile, snapshot):
        raise RuntimeError("device fell over")

    eng.run_tile = boom
    with pytest.raises(RuntimeError, match="fell over"):
        sched.step()
    # nothing lost: the tile went back to the head of the queue
    assert sched.pending == 3
    assert all(r.status == "queued" and r.score is None for r in reqs)
    eng.run_tile = MTLScoringEngine.run_tile.__get__(eng)
    assert sched.run_until_idle() == 3
    assert all(r.status == "done" for r in reqs)


def test_concurrent_submit_and_publish_thread_safety(W):
    """Training thread publishes while a serving thread steps: every
    request completes exactly once on SOME published version."""
    versions = [ModelSnapshot(version=v, W=W * v) for v in range(2, 12)]
    eng = MTLScoringEngine(W, batch=8, version=1)
    sched = ContinuousBatchingScheduler(eng)
    reqs = _requests(64, seed=8)

    def trainer():
        for snap in versions:
            sched.publish(snap)

    t = threading.Thread(target=trainer)
    for r in reqs[:32]:
        sched.submit(r)
    t.start()
    done = []
    backlog = list(reqs[32:])
    while len(done) < len(reqs):
        done += sched.step()
        while backlog and sched.pending < 8:  # feed the rest mid-flight
            sched.submit(backlog.pop(0))
    t.join()
    assert len(done) == 64 and all(r.status == "done" for r in reqs)
    assert all(1 <= r.snapshot_version <= 11 for r in reqs)
    assert sched.version == 11


# ---------------------------------------------------------------------------
# metrics unit behavior
# ---------------------------------------------------------------------------
def test_latency_histogram_percentiles_and_decimation():
    h = LatencyHistogram(max_samples=64)
    for v in np.linspace(0.001, 0.1, 1000):
        h.observe(float(v))
    assert h.count == 1000
    assert h.percentile(50) == pytest.approx(0.0505, rel=0.1)
    assert h.percentile(99) <= 0.1 and h.summary()["max_s"] == pytest.approx(0.1)
    assert sum(b["count"] for b in h.buckets()) == 1000
    assert len(h._samples) <= 64


def test_metrics_summary_shape():
    clk = ManualClock()
    m = ServingMetrics(slo_s=0.5, clock=clk)
    m.on_submit(3)
    clk.advance(2.0)
    m.on_complete(3, 0.7, violated=True)
    m.on_tile(3, 4)
    s = m.summary()
    assert s["throughput_rps"] == pytest.approx(0.5)
    assert s["slo_violations"] == 1 and s["per_task"]["3"]["slo_violations"] == 1
    assert s["tile_fill"] == 0.75
    assert s["latency"]["p50_s"] == pytest.approx(0.7)


# ---------------------------------------------------------------------------
# transport subscription -> live serving hot-swap
# ---------------------------------------------------------------------------
def test_transport_subscription_feeds_scheduler(small_problem, small_cfg):
    """core/transport.py hook: a Sigma install notifies subscribers with
    raw-size (W, sigma, version) — wired straight into a scheduler, every
    install hot-swaps the served weights."""
    import dataclasses as dc

    from repro.core.omega_regularizers import resolve_regularizer
    from repro.core.transport import get_transport

    cfg = dc.replace(small_cfg, n_workers=1, transport="threaded")
    transport = get_transport("threaded").factory()
    reg = resolve_regularizer(cfg, None)
    transport.setup(
        cfg, small_problem.train, mesh=None, axes=None, reg=reg,
        init=None, track=False,
    )
    try:
        m, d = small_problem.train.m, small_problem.train.d
        eng = MTLScoringEngine(np.zeros((m, d), np.float32), batch=4, version=0)
        sched = ContinuousBatchingScheduler(eng, clock=ManualClock())
        seen = []
        transport.subscribe(lambda W, sigma, v: seen.append((W.shape, sigma.shape, v)))
        transport.subscribe(sched.publish_weights)

        rng = np.random.RandomState(0)
        sig = np.eye(m, dtype=np.float32) / m
        for _ in range(2):
            transport.install_sigma(
                jnp.asarray(sig), jnp.asarray(np.eye(m, dtype=np.float32) * m),
                defer=False,
            )
        assert [v for _, _, v in seen] == [1, 2]
        assert all(ws == (m, d) and ss == (m, m) for ws, ss, _ in seen)
        assert sched.version == 2
        r = ScoreRequest(task=0, x=rng.randn(d).astype(np.float32))
        sched.submit(r)
        sched.step()
        assert r.snapshot_version == 2 and r.score is not None
    finally:
        transport.close()


# ---------------------------------------------------------------------------
# load test (CI slow job: -m "slow or load")
# ---------------------------------------------------------------------------
@pytest.mark.load
def test_load_generator_records_bench(tmp_path):
    """Queued arrivals, mixed tasks, straggler tiles — through the real
    benchmark harness, recording p50/p95/p99 latency, throughput and
    SLO-violation counts to a BENCH_serving.json."""
    import importlib.util
    import json
    import os

    bench = os.path.join(
        os.path.dirname(__file__), "..", "benchmarks", "bench_serving.py"
    )
    spec = importlib.util.spec_from_file_location("bench_serving", bench)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    out = tmp_path / "BENCH_serving.json"
    mod.main([
        "--requests", "400", "--batch", "16", "--tasks", "8", "--d", "24",
        "--rate", "2000", "--slo-ms", "50", "--straggler-every", "7",
        "--out", str(out),
    ])
    rows = json.loads(out.read_text())
    load_rows = [r for r in rows if r["kind"] == "load"]
    assert load_rows, "bench wrote no load rows"
    for row in load_rows:
        s = row["metrics"]
        assert s["completed"] + s["expired"] == row["requests"] == s["submitted"]
        lat = s["latency"]
        assert 0 < lat["p50_s"] <= lat["p95_s"] <= lat["p99_s"]
        assert s["throughput_rps"] > 0 and s["slo_violations"] >= 0
        assert s["swaps"] >= 1  # the bench hot-swaps mid-load
    # head-of-line fix row: per-slot decode batching must decouple short-
    # request p99 from the longest in-flight generation
    (inter,) = [r for r in rows if r["kind"] == "lm_interleave"]
    assert inter["streaming"]["short_p99_s"] < inter["blocking"]["short_p99_s"]
    assert inter["streaming"]["slot_occupancy"] > 0
    # AOT warmup row: warm-start worst case beat the cold trace+compile
    (wc,) = [r for r in rows if r["kind"] == "warm_vs_cold"]
    for eng in ("lm", "mtl"):
        assert wc[eng]["warm_max_s"] < wc[eng]["cold_first_s"]
