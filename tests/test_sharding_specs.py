"""Partition-spec coverage and validity for every arch (no devices needed:
specs are pure metadata; validity = axes exist + dims divisible)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config

# a fake mesh-shape view: (data=16, model=16) and (pod=2, data=16, model=16)
MESHES = {"single": {"data": 16, "model": 16}, "multi": {"pod": 2, "data": 16, "model": 16}}


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)


def _check_tree(cfg, mesh_shape, mode):
    from repro.models.sharding import param_pspecs
    import repro.models.transformer as tf

    shapes = tf.param_shapes(cfg)
    specs = param_pspecs(cfg, shapes, FakeMesh(mesh_shape), mode=mode)
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(
        specs, is_leaf=lambda s: isinstance(s, P)
    )
    assert len(flat_shapes) == len(flat_specs)
    for leaf, spec in zip(flat_shapes, flat_specs):
        assert len(spec) <= len(leaf.shape), (leaf.shape, spec)
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                assert a in mesh_shape, (a, spec)
                total *= mesh_shape[a]
            assert dim % total == 0, (leaf.shape, spec, dim, total)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_name", ["single", "multi"])
@pytest.mark.parametrize("mode", ["serve", "train"])
def test_param_specs_valid(arch, mesh_name, mode):
    _check_tree(get_config(arch), MESHES[mesh_name], mode)


@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "kimi-k2-1t-a32b"])
def test_moe_experts_sharded(arch):
    from repro.models.sharding import param_pspecs
    import repro.models.transformer as tf

    cfg = get_config(arch)
    shapes = tf.param_shapes(cfg)
    specs = param_pspecs(cfg, shapes, FakeMesh(MESHES["single"]), mode="train")
    moe_spec = specs["layers"]["moe"]["w_up"]
    # stacked (L, E, d, f): expert dim sharded over 'model'
    assert tuple(moe_spec)[1] == "model", moe_spec


def test_train_mode_shards_more_than_serve():
    """FSDP must strictly reduce per-device parameter bytes for a big arch."""
    from repro.models.sharding import param_pspecs
    import repro.models.transformer as tf

    cfg = get_config("qwen1_5-32b")
    shapes = tf.param_shapes(cfg)
    mesh = FakeMesh(MESHES["single"])

    def bytes_per_dev(mode):
        specs = param_pspecs(cfg, shapes, mesh, mode=mode)
        tot = 0
        for leaf, spec in zip(
            jax.tree.leaves(shapes),
            jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P)),
        ):
            denom = 1
            for entry in tuple(spec):
                if entry is None:
                    continue
                for a in entry if isinstance(entry, tuple) else (entry,):
                    denom *= mesh.shape[a]
            tot += int(np.prod(leaf.shape)) * leaf.dtype.itemsize // denom
        return tot

    assert bytes_per_dev("train") < bytes_per_dev("serve") / 4
