"""Structured Sigma (core/sigma_view.py) — views, parity, wire, serve.

The tentpole contract of the structured-Sigma PR:

  * SigmaView ops (matvec / rows / diag / col_block_matvec / pad / unpad /
    factors) agree with the materialized dense Sigma on every view class.
  * ``low_rank_diag`` at r = m reproduces ``trace_constraint``'s Sigma and
    iterates through all three engines and the simulated + threaded
    transports (cross-engine tolerance covers eigensolver sensitivity to
    float-association differences, not algorithmic drift).
  * ``graphical_lasso`` at penalty=0 equals its own dense trace-normalized
    coupling; any penalty keeps Sigma PD and trace-1.
  * Every registry member yields a PSD trace-normalized Sigma at
    m in {1, 2, 3, 257} (satellite sweep; hypothesis fuzz when available).
  * The Omega step rejects non-finite W with a clear ValueError.
  * Dense members warn once when resolved at m above the threshold.
  * The serve-path gather returns exact Sigma rows from the factors.
  * Snapshots from structured servers ship the diagonal, not (m_loc, m)
    rows, and the block solver accepts both wire shapes identically.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DMTRLEstimator
from repro.core.async_dmtrl import AsyncOptions
from repro.core.dmtrl import DMTRLConfig
from repro.core.engines import get_engine
from repro.core.omega_regularizers import (
    DENSE_SIGMA_WARN_THRESHOLD,
    get_regularizer,
    resolve_regularizer,
)
from repro.core.sigma_view import (
    DenseSigma,
    LowRankDiagSigma,
    SigmaView,
    SparseSigma,
    as_view,
    maybe_dense,
    view_from_factors,
)
from repro.core.transport import Snapshot, make_block_solver, payload_nbytes
from repro.data.synthetic import synthetic


def _problem(m=6, d=8, seed=3):
    return synthetic(1, m=m, d=d, n_train_avg=20, n_test_avg=8, seed=seed)


def _cfg(**kw):
    base = dict(outer_iters=2, rounds=3, lam=0.1, solver="block_gram")
    base.update(kw)
    return DMTRLConfig(**base)


def _views(m=7, r=3, k=2, seed=0):
    """One instance of each view class plus its dense reference."""
    rng = np.random.RandomState(seed)
    U = jnp.asarray(rng.randn(m, r).astype(np.float32))
    core = jnp.asarray(np.diag(rng.rand(r).astype(np.float32) + 0.1))
    d = jnp.asarray(rng.rand(m).astype(np.float32) + 0.05)
    lr = LowRankDiagSigma(U=U, core=core, d=d)

    cols = np.zeros((m, k), np.int32)
    vals = np.zeros((m, k), np.float32)
    for i in range(m):  # symmetric band: couple i with i+-1
        js = [j for j in (i - 1, i + 1) if 0 <= j < m][:k]
        cols[i, : len(js)] = js
        vals[i, : len(js)] = 0.01 * (1 + np.arange(len(js)))
    # symmetrize values so the matrix (not just the pattern) is symmetric
    dense_off = np.zeros((m, m), np.float32)
    for i in range(m):
        for s in range(k):
            if vals[i, s]:
                dense_off[i, cols[i, s]] = vals[i, s]
    dense_off = 0.5 * (dense_off + dense_off.T)
    for i in range(m):
        for s in range(k):
            if vals[i, s]:
                vals[i, s] = dense_off[i, cols[i, s]]
    sp = SparseSigma(
        diag_v=jnp.asarray(rng.rand(m).astype(np.float32) + 0.5),
        cols=jnp.asarray(cols),
        vals=jnp.asarray(vals),
    )
    dn = DenseSigma(sigma=jnp.asarray(lr.dense()))
    return [lr, sp, dn]


# ---------------------------------------------------------------------------
# view-op consistency
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("idx", [0, 1, 2], ids=["lowrank", "sparse", "dense"])
def test_view_ops_match_dense(idx):
    view = _views()[idx]
    m = view.m
    S = np.asarray(view.dense())
    assert np.allclose(S, S.T, atol=1e-6)
    rng = np.random.RandomState(1)
    v = jnp.asarray(rng.randn(m).astype(np.float32))
    V = jnp.asarray(rng.randn(m, 3).astype(np.float32))
    np.testing.assert_allclose(view.matvec(v), S @ np.asarray(v), atol=1e-5)
    np.testing.assert_allclose(view.matvec(V), S @ np.asarray(V), atol=1e-5)
    np.testing.assert_allclose(np.asarray(view.diag()), np.diag(S), atol=1e-6)
    idxs = jnp.asarray([0, m - 1, 2], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(view.rows(idxs)), S[np.asarray(idxs)], atol=1e-6
    )
    db = jnp.asarray(rng.randn(3, 4).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(view.col_block_matvec(2, db)),
        S[:, 2:5] @ np.asarray(db),
        atol=1e-5,
    )
    assert float(view.trace()) == pytest.approx(float(np.trace(S)), rel=1e-5)
    assert view.nbytes() > 0
    assert np.isfinite(float(view.logdet_bound()))


@pytest.mark.parametrize("idx", [0, 1, 2], ids=["lowrank", "sparse", "dense"])
def test_view_pad_unpad_roundtrip(idx):
    view = _views()[idx]
    m = view.m
    padded = view.pad(m + 3, 1e-6)
    assert padded.m == m + 3
    Sp = np.asarray(padded.dense())
    np.testing.assert_allclose(Sp[:m, :m], np.asarray(view.dense()), atol=1e-6)
    np.testing.assert_allclose(np.diag(Sp)[m:], 1e-6, atol=1e-8)
    assert np.abs(Sp[m:, :m]).max() == 0.0
    back = padded.unpad(m)
    np.testing.assert_allclose(
        np.asarray(back.dense()), np.asarray(view.dense()), atol=1e-6
    )


@pytest.mark.parametrize("idx", [0, 1, 2], ids=["lowrank", "sparse", "dense"])
def test_view_wire_factors_roundtrip(idx):
    view = _views()[idx]
    wire = view.factors()
    # wire leaves are host numpy (+ the kind tag), picklable as-is
    assert all(
        isinstance(x, np.ndarray) for k, x in wire.items() if k != "kind"
    )
    back = view_from_factors(wire)
    assert type(back) is type(view)
    np.testing.assert_allclose(
        np.asarray(back.dense()), np.asarray(view.dense()), atol=0
    )


def test_view_is_a_jit_pytree():
    view = _views()[0]

    @jax.jit
    def f(sv, v):
        return sv.matvec(v)

    v = jnp.ones((view.m,), jnp.float32)
    np.testing.assert_allclose(f(view, v), view.matvec(v), atol=1e-6)


def test_as_view_and_maybe_dense():
    S = jnp.eye(4) / 4.0
    v = as_view(S)
    assert isinstance(v, DenseSigma)
    assert isinstance(maybe_dense(v), np.ndarray)
    lr = _views()[0]
    assert maybe_dense(lr, limit=2) is lr  # too big to materialize
    assert isinstance(maybe_dense(lr, limit=1000), np.ndarray)


# ---------------------------------------------------------------------------
# dense-vs-structured parity (tentpole acceptance)
# ---------------------------------------------------------------------------
def test_low_rank_full_rank_matches_trace_constraint_reference():
    sp = _problem()
    cfg = _cfg()
    ref = get_engine("reference")
    dense = ref.run(cfg, sp.train, regularizer=get_regularizer("trace_constraint"))
    lr = ref.run(
        cfg, sp.train,
        regularizer=get_regularizer("low_rank_diag", rank=sp.train.m),
    )
    assert isinstance(lr.sigma_view, LowRankDiagSigma)
    np.testing.assert_allclose(lr.sigma, dense.sigma, atol=1e-3)
    np.testing.assert_allclose(lr.W, dense.W, atol=2e-3)


@pytest.mark.parametrize("engine", ["distributed", "async"])
def test_low_rank_full_rank_cross_engine(engine, one_device_mesh):
    sp = _problem()
    cfg = _cfg()
    ref = get_engine("reference")
    anchor = ref.run(
        cfg, sp.train,
        regularizer=get_regularizer("low_rank_diag", rank=sp.train.m),
    )
    res = get_engine(engine).run(
        cfg, sp.train, mesh=one_device_mesh,
        regularizer=get_regularizer("low_rank_diag", rank=sp.train.m),
    )
    # mesh psum reassociates floats; the eigensolver amplifies that into
    # rotated (equivalent) factors — compare iterates loosely, Sigma tightly
    np.testing.assert_allclose(res.W, anchor.W, atol=2e-2)
    np.testing.assert_allclose(res.sigma, anchor.sigma, atol=2e-3)


@pytest.mark.parametrize("transport", ["simulated", "threaded"])
def test_structured_members_through_transports(transport, one_device_mesh):
    sp = _problem()
    cfg = _cfg()
    ref = get_engine("reference")
    eng = get_engine("async")
    for reg_name, params in (
        ("low_rank_diag", dict(rank=sp.train.m)),
        ("graphical_lasso", dict(penalty=0.0)),
    ):
        anchor = ref.run(
            cfg, sp.train, regularizer=get_regularizer(reg_name, **params)
        )
        n_workers = None if transport == "simulated" else 2
        res = eng.run(
            cfg, sp.train, mesh=one_device_mesh,
            options=AsyncOptions(tau=0, transport=transport, n_workers=n_workers),
            regularizer=get_regularizer(reg_name, **params),
        )
        np.testing.assert_allclose(res.W, anchor.W, atol=2e-2)
        np.testing.assert_allclose(res.sigma, anchor.sigma, atol=2e-3)
        if transport == "threaded":
            # host servers keep the factors end-to-end
            assert isinstance(res.sigma_view, SigmaView)


def test_graphical_lasso_zero_penalty_is_dense_coupling():
    rng = np.random.RandomState(0)
    W = jnp.asarray(rng.randn(7, 5).astype(np.float32))
    sigma, om = get_regularizer("graphical_lasso", penalty=0.0).step(W, 1e-6)
    assert om is None  # sparse Sigma has no cheap structured inverse
    Wn = np.asarray(W, np.float64)
    S = Wn @ Wn.T / (Wn * Wn).sum()
    S = S + np.eye(7) * 1e-6
    S = S / np.trace(S)
    np.testing.assert_allclose(maybe_dense(sigma), S, atol=1e-6)


def test_graphical_lasso_positive_penalty_sparsifies_and_stays_pd():
    sp = _problem(m=8)
    res = get_engine("reference").run(
        _cfg(), sp.train, regularizer=get_regularizer("graphical_lasso", penalty=2.0)
    )
    assert isinstance(res.sigma_view, SparseSigma)
    S = np.asarray(res.sigma)
    off = S - np.diag(np.diag(S))
    dense_res = get_engine("reference").run(
        _cfg(), sp.train, regularizer=get_regularizer("graphical_lasso", penalty=0.0)
    )
    dense_off = dense_res.sigma - np.diag(np.diag(dense_res.sigma))
    assert np.count_nonzero(off) <= np.count_nonzero(np.abs(dense_off) > 1e-12)
    assert np.linalg.eigvalsh(S).min() > 0
    assert np.trace(S) == pytest.approx(1.0, abs=1e-4)


# ---------------------------------------------------------------------------
# satellite: PSD + trace-normalized across ALL registry members
# ---------------------------------------------------------------------------
def _member_sigma(name, m, seed=0):
    """One Omega-step Sigma (or the init Sigma for fixed members) at size m."""
    params = {}
    if name == "graph_laplacian":
        A = np.zeros((m, m))
        for i in range(m - 1):
            A[i, i + 1] = A[i + 1, i] = 1.0
        params["adjacency"] = A
    reg = get_regularizer(name, **params)
    if reg.learns:
        W = jnp.asarray(np.random.RandomState(seed).randn(m, 5).astype(np.float32))
        sigma, _ = reg.step(W, 1e-6)
    else:
        sigma, _ = reg.init(m, jnp.float32)
    return maybe_dense(sigma, limit=10_000)


@pytest.mark.parametrize("m", [1, 2, 3, 257])
def test_all_members_sigma_psd_trace_normalized(m):
    from repro.core import available_regularizers

    for name in sorted(available_regularizers()):
        S = np.asarray(_member_sigma(name, m), np.float64)
        assert S.shape == (m, m), name
        assert np.allclose(S, S.T, atol=1e-5), name
        assert np.linalg.eigvalsh(S).min() > -1e-5, name
        assert np.trace(S) == pytest.approx(1.0, abs=1e-3), name


def test_all_members_sigma_psd_hypothesis_fuzz():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(m=st.integers(1, 40), seed=st.integers(0, 5))
    def check(m, seed):
        for name in ("trace_constraint", "low_rank_diag", "graphical_lasso"):
            S = np.asarray(_member_sigma(name, m, seed), np.float64)
            assert np.linalg.eigvalsh(S).min() > -1e-5
            assert abs(np.trace(S) - 1.0) < 1e-3

    check()


# ---------------------------------------------------------------------------
# satellite: non-finite W guard
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "name", ["trace_constraint", "low_rank_diag", "graphical_lasso"]
)
def test_omega_step_rejects_non_finite_w(name):
    reg = get_regularizer(name)
    W = jnp.ones((4, 3))
    W = W.at[1, 2].set(jnp.nan)
    with pytest.raises(ValueError, match="non-finite"):
        reg.step(W, 1e-6)
    W = jnp.ones((4, 3)).at[0, 0].set(jnp.inf)
    with pytest.raises(ValueError, match="non-finite"):
        reg.step(W, 1e-6)


def test_finite_guard_survives_dataclasses_replace():
    reg = get_regularizer("trace_constraint")
    reg2 = dataclasses.replace(reg, description="copy")
    W = jnp.full((3, 2), jnp.nan)
    with pytest.raises(ValueError, match="non-finite"):
        reg2.step(W, 1e-6)


# ---------------------------------------------------------------------------
# satellite: one-time dense-at-scale warning
# ---------------------------------------------------------------------------
def test_dense_member_warns_once_above_threshold():
    from repro.core import omega_regularizers as mod

    cfg = DMTRLConfig()
    mod._dense_scale_warned.discard("trace_constraint")
    try:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            resolve_regularizer(cfg, m=4, dense_warn_threshold=2)
            resolve_regularizer(cfg, m=4, dense_warn_threshold=2)  # once only
        msgs = [x for x in w if "dense" in str(x.message).lower()]
        assert len(msgs) == 1
        assert "low_rank_diag" in str(msgs[0].message)
        # structured members never warn
        with warnings.catch_warnings(record=True) as w2:
            warnings.simplefilter("always")
            resolve_regularizer(
                cfg, regularizer=get_regularizer("low_rank_diag"),
                m=10_000, dense_warn_threshold=2,
            )
        assert not [x for x in w2 if "dense" in str(x.message).lower()]
        assert DENSE_SIGMA_WARN_THRESHOLD >= 1
    finally:
        mod._dense_scale_warned.discard("trace_constraint")


# ---------------------------------------------------------------------------
# wire format: structured snapshots ship the diagonal
# ---------------------------------------------------------------------------
def test_snapshot_payload_structured_smaller_than_dense():
    m, m_loc, d, n_max = 64, 8, 5, 10
    W_rows = np.zeros((m_loc, d), np.float32)
    alpha_rows = np.zeros((m_loc, n_max), np.float32)
    dense = Snapshot(
        W_rows=W_rows, sigma_rows=np.zeros((m_loc, m), np.float32),
        alpha_rows=alpha_rows, version=0,
    )
    structured = Snapshot(
        W_rows=W_rows, sigma_rows=None, alpha_rows=alpha_rows, version=0,
        sigma_diag=np.zeros((m_loc,), np.float32),
    )
    assert payload_nbytes(structured) < payload_nbytes(dense)
    assert payload_nbytes(dense) - payload_nbytes(structured) == 4 * m_loc * (m - 1)


def test_block_solver_accepts_rows_and_diag_identically():
    sp = _problem(m=4, d=6)
    data = sp.train
    cfg = _cfg()
    solve = make_block_solver(cfg, data.n_max, rho=1.0)
    rng = np.random.RandomState(0)
    sigma = np.eye(data.m, dtype=np.float32) / data.m + 0.01
    alpha = jnp.zeros((data.m, data.n_max), jnp.float32)
    W = jnp.asarray(rng.randn(data.m, data.d).astype(np.float32))
    tids = jnp.arange(data.m, dtype=jnp.int32)
    key = jax.random.PRNGKey(0)
    a1, b1 = solve(
        data.x, data.y, alpha, W, data.n, jnp.asarray(sigma), tids, key
    )
    a2, b2 = solve(
        data.x, data.y, alpha, W, data.n, jnp.asarray(np.diag(sigma)), tids, key
    )
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))


# ---------------------------------------------------------------------------
# serve path: sparse Sigma-row gather
# ---------------------------------------------------------------------------
def test_serving_engine_gathers_sigma_rows_from_factors():
    from repro.serve.mtl import ScoreRequest

    sp = _problem()
    est = DMTRLEstimator(
        engine="reference", regularizer="low_rank_diag",
        regularizer_params={"rank": sp.train.m},
        outer_iters=2, rounds=3, lam=0.1,
    )
    est.fit(sp.train)
    assert isinstance(est.sigma_view_, LowRankDiagSigma)
    assert isinstance(est.model_snapshot().sigma, SigmaView)

    eng = est.scoring_engine(batch=4, gather_sigma_rows=True)
    tasks = [0, 3, 5, 1, 2]
    rows = eng.sigma_rows_for(tasks)
    dense = np.asarray(est.sigma_view_.dense())
    np.testing.assert_allclose(rows, dense[tasks], atol=1e-6)

    reqs = [
        ScoreRequest(task=t, x=np.ones((sp.train.d,), np.float32))
        for t in tasks[:4]
    ]
    eng.run_tile(reqs, eng.model_snapshot())
    for r in reqs:
        assert r.score is not None
        assert r.sigma_row is not None
        np.testing.assert_allclose(r.sigma_row, dense[r.task], atol=1e-6)


def test_serving_engine_without_sigma_raises_on_gather():
    eng_W = np.zeros((3, 2), np.float32)
    from repro.serve.mtl import MTLScoringEngine

    eng = MTLScoringEngine(eng_W, batch=2)
    with pytest.raises(ValueError, match="no Sigma"):
        eng.sigma_rows_for([0, 1])


def test_estimator_partial_fit_roundtrips_structured_state():
    sp = _problem()
    est = DMTRLEstimator(
        engine="reference", regularizer="low_rank_diag",
        regularizer_params={"rank": 4},
        outer_iters=1, rounds=2, lam=0.1,
    )
    est.fit(sp.train)
    v1 = est.sigma_view_
    est.partial_fit(sp.train)
    assert isinstance(est.sigma_view_, LowRankDiagSigma)
    assert est.sigma_view_ is not v1
    assert est.n_fit_calls_ == 2
