"""Solver-backend registry: cross-backend iterate parity + registry API.

All backends share the key->coords derivation of ``sdca.sample_coords``, so
for one (key, shape, loss) triple every backend walks the SAME sampled
coordinate order and must produce the same iterate sequence:

  * naive / pallas_block vs block_gram: equal up to float-op reordering.
  * pallas_round vs block_gram: BIT-equal in interpret mode (the fused
    kernel replays the block-Gram recursion op for op, acceptance anchor).

hypothesis is an optional test dependency (see pyproject's [test] extra);
the property sweep imports it via ``pytest.importorskip`` at call time so a
missing install skips just that test instead of erroring collection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.losses import get_loss
from repro.core.solver_backends import (
    available_backends,
    get_backend,
)

KERNEL_LOSSES = ("hinge", "squared", "smoothed_hinge")
BACKENDS = ("naive", "block_gram", "pallas_block", "pallas_round")


def _problem(seed, n, d, n_valid):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (n, d))
    y = jnp.sign(jax.random.normal(ks[1], (n,)))
    y = jnp.where(y == 0, 1.0, y)
    alpha = 0.1 * jax.random.normal(ks[2], (n,))
    w = 0.05 * jax.random.normal(ks[3], (d,))
    return x, y, alpha, w, jnp.int32(n_valid), jnp.float32(0.25), ks[0]


def _run_all(loss_name, seed, n, d, n_valid, H, block):
    loss = get_loss(loss_name)
    args = _problem(seed, n, d, n_valid)
    out = {}
    for name in BACKENDS:
        be = get_backend(name)
        solve = be.make(loss, 2.0, 1e-3, be.round_local_iters(H, block), block=block)
        da, r = solve(*args)
        out[name] = (np.asarray(da), np.asarray(r))
    return out


@pytest.mark.parametrize("loss_name", KERNEL_LOSSES)
@pytest.mark.parametrize("n,d,H,block", [(70, 33, 96, 32), (40, 17, 64, 16)])
def test_all_backends_same_iterates(loss_name, n, d, H, block):
    out = _run_all(loss_name, seed=n * d, n=n, d=d, n_valid=n - 5, H=H, block=block)
    da0, r0 = out["block_gram"]
    for name in ("naive", "pallas_block"):
        np.testing.assert_allclose(out[name][0], da0, atol=2e-5, err_msg=name)
        np.testing.assert_allclose(out[name][1], r0, atol=2e-5, err_msg=name)
    # acceptance anchor: the fused round kernel replays block_gram bit-exactly
    np.testing.assert_array_equal(out["pallas_round"][0], da0)
    np.testing.assert_array_equal(out["pallas_round"][1], r0)


@pytest.mark.parametrize("loss_name", ["logistic", "eps_insensitive"])
def test_kernel_fallback_losses_still_parity(loss_name):
    """Losses without a closed-form kernel delta fall back to references
    with the same iterate semantics (not bit-equal: different float path)."""
    out = _run_all(loss_name, seed=3, n=48, d=20, n_valid=48, H=64, block=32)
    da0, r0 = out["block_gram"]
    for name in ("pallas_block", "pallas_round"):
        np.testing.assert_allclose(out[name][0], da0, atol=2e-5, err_msg=name)
        np.testing.assert_allclose(out[name][1], r0, atol=2e-5, err_msg=name)


def test_backend_parity_property():
    """hypothesis sweep: random shapes x all three kernel losses agree."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(
        loss_name=st.sampled_from(KERNEL_LOSSES),
        n=st.integers(20, 90),
        d=st.integers(5, 40),
        nb=st.integers(1, 3),
        block=st.sampled_from([8, 16, 32]),
        pad=st.integers(0, 10),
        seed=st.integers(0, 2**16),
    )
    def check(loss_name, n, d, nb, block, pad, seed):
        n_valid = max(n - pad, 1)
        out = _run_all(
            loss_name, seed=seed, n=n, d=d, n_valid=n_valid, H=nb * block,
            block=block,
        )
        da0, r0 = out["block_gram"]
        for name in ("naive", "pallas_block"):
            np.testing.assert_allclose(out[name][0], da0, atol=5e-5)
            np.testing.assert_allclose(out[name][1], r0, atol=5e-5)
        np.testing.assert_array_equal(out["pallas_round"][0], da0)
        np.testing.assert_array_equal(out["pallas_round"][1], r0)

    check()


def test_registry_api():
    have = available_backends()
    assert set(BACKENDS) <= set(have)
    with pytest.raises(KeyError, match="unknown solver backend"):
        get_backend("nope")
    # pallas launch accounting: the fused kernel is ONE call per round
    assert get_backend("pallas_round").pallas_calls_per_round(256, 64) == 1
    assert get_backend("pallas_block").pallas_calls_per_round(256, 64) == 4
    assert get_backend("block_gram").pallas_calls_per_round(256, 64) == 0
    assert get_backend("naive").pallas_calls_per_round(256, 64) == 0
    # H alignment contract
    assert get_backend("block_gram").round_local_iters(100, 64) == 128
    assert get_backend("naive").round_local_iters(100, 64) == 100


def test_pallas_backends_reject_sharded_features():
    loss = get_loss("hinge")
    for name in ("pallas_block", "pallas_round"):
        assert not get_backend(name).supports_sharded_features
        with pytest.raises(ValueError, match="sharded feature"):
            get_backend(name).make(loss, 2.0, 1e-3, 64, block=32, axis_name="model")


def test_mesh_engines_run_pallas_backends(one_device_mesh):
    """fit_distributed and fit_async must trace pallas backends under
    shard_map (replication checking has no pallas_call rule — the round
    builder must route through compat.shard_map_unchecked) and keep the
    tau=0 bit-parity anchor."""
    from repro.core import DMTRLConfig, MeshAxes, fit_async, fit_distributed
    from repro.data.synthetic import synthetic

    data = synthetic(1, m=3, d=12, n_train_avg=24, n_test_avg=6, seed=11).train
    ax = MeshAxes(data="data")
    for name in ("pallas_block", "pallas_round"):
        cfg = DMTRLConfig(
            loss="hinge", lam=1e-3, outer_iters=1, rounds=2, local_iters=16,
            solver=name, block_size=16, seed=0,
        )
        W1, _, st1, h1 = fit_distributed(cfg, data, one_device_mesh, ax)
        W2, _, st2, _ = fit_async(cfg, data, one_device_mesh, ax)
        assert np.array_equal(W1, W2), name
        assert np.array_equal(np.asarray(st1.alpha), np.asarray(st2.alpha)), name
        assert h1["gap"][-1] < h1["gap"][0], name


def test_engine_fit_runs_on_every_backend():
    """The whole Algorithm-1 driver works with each registered backend.

    (Bit-equality of pallas_round vs block_gram is asserted per task above;
    under the engine's vmap+jit XLA batches the jnp matmuls differently, so
    across a full fit the runs agree only to float tolerance.)"""
    from repro.core import DMTRLConfig, fit
    from repro.data.synthetic import synthetic

    data = synthetic(1, m=3, d=12, n_train_avg=24, n_test_avg=6, seed=11).train
    results = {}
    for name in BACKENDS:
        cfg = DMTRLConfig(
            loss="hinge", lam=1e-3, outer_iters=1, rounds=2, local_iters=16,
            solver=name, block_size=16, seed=0,
        )
        results[name] = np.asarray(fit(cfg, data, track=False).W)
    for name in ("naive", "pallas_block", "pallas_round"):
        np.testing.assert_allclose(
            results[name], results["block_gram"], atol=1e-4, err_msg=name
        )
