"""Training loop: loss decreases, microbatching equivalence, checkpoints."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokens import SyntheticTokenPipeline, TokenPipelineConfig
from repro.models import init_params
from repro.train import AdamW, train
from repro.train import checkpoint as ckpt
from repro.train.loop import make_train_step


def _data_iter(cfg, batch=4, seq=64, seed=0):
    pipe = SyntheticTokenPipeline(
        TokenPipelineConfig(cfg.vocab_size, seq, batch, seed)
    )
    return iter(pipe)


@pytest.mark.slow
def test_loss_decreases_small_model():
    cfg = get_config("gemma3-1b").reduced()
    opt = AdamW(lr=3e-3, warmup_steps=5, total_steps=60)
    _, _, hist = train(cfg, opt, _data_iter(cfg), steps=60)
    first = np.mean([h["loss"] for h in hist[:2]])
    last = np.mean([h["loss"] for h in hist[-2:]])
    assert last < first - 0.3, (first, last)


@pytest.mark.slow
def test_microbatching_matches_full_batch():
    cfg = get_config("qwen1_5-4b").reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = AdamW(lr=1e-3, warmup_steps=1, total_steps=10)
    batch = next(_data_iter(cfg, batch=4, seq=32))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    s1 = jax.jit(make_train_step(cfg, opt, microbatches=1))
    s4 = jax.jit(make_train_step(cfg, opt, microbatches=4))
    p1, _, m1 = s1(params, opt.init(params), batch)
    p4, _, m4 = s4(params, opt.init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=5e-3
        )


def test_checkpoint_roundtrip():
    cfg = get_config("whisper-tiny").reduced()
    params = init_params(cfg, jax.random.PRNGKey(1))
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "step_1")
        ckpt.save(path, params, step=1, meta={"arch": cfg.name})
        zeros = jax.tree.map(lambda a: jnp.zeros_like(a), params)
        restored = ckpt.load(path, zeros)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert ckpt.latest_step(path) == 1


def test_checkpoint_shape_mismatch_raises():
    cfg = get_config("whisper-tiny").reduced()
    params = init_params(cfg, jax.random.PRNGKey(2))
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "c")
        ckpt.save(path, {"w": jnp.ones((3, 3))})
        with pytest.raises((KeyError, ValueError)):
            ckpt.load(path, {"w": jnp.ones((4, 4))})


def test_adamw_schedule():
    opt = AdamW(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(opt.schedule(jnp.int32(0))) == pytest.approx(0.0)
    assert float(opt.schedule(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(opt.schedule(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)


def test_data_pipeline_determinism():
    cfg = TokenPipelineConfig(vocab_size=512, seq_len=16, global_batch=2, seed=7)
    a = SyntheticTokenPipeline(cfg).batch(3)
    b = SyntheticTokenPipeline(cfg).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
