"""Pluggable transport layer (core/transport.py).

Anchors:
  * registry surface: get_transport("simulated"|"threaded"|"multiprocess").
  * golden replay: the ``simulated`` transport reproduces the integer event
    histories recorded from the pre-refactor engine bit-exactly
    (tests/golden/async_histories.json; the G=4 straggler cases replay in a
    subprocess and are marked slow).
  * cross-transport parity: threaded/multiprocess at tau=0 match the
    ``reference`` engine to float-association tolerance for any worker
    count (round-boundary snapshot versioning), and all transports agree
    with each other.
  * SSP-gate correctness under genuinely nondeterministic thread arrivals:
    observed lag never exceeds tau.
  * cost-aware tau="auto" (staleness_budget) controller transitions.
  * the synchronous engine's degenerate tau=0 receipts flow through the
    same CommitReceipt -> staleness_summary path.
  * deprecation hygiene: legacy wrappers emit exactly one
    DeprecationWarning and legacy async_delays config kwargs still route.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro.core import AsyncOptions, DMTRLConfig, DMTRLEstimator, MeshAxes
from repro.core import convergence as cv
from repro.core.async_dmtrl import fit_async
from repro.core.dmtrl import fit as fit_reference
from repro.core.transport import (
    _adapt_tau,
    available_transports,
    get_transport,
    make_block_solver,
)
from repro.data.synthetic import synthetic

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(REPO, "tests", "golden", "async_histories.json")

ATOL = 5e-5  # float-association tolerance for cross-transport parity


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def ref_result(small_problem, small_cfg):
    return fit_reference(small_cfg, small_problem.train)


def _fit_transport(cfg, data, transport, n_workers, mesh=None, **opt_kw):
    opts = AsyncOptions(transport=transport, n_workers=n_workers, **opt_kw)
    return fit_async(cfg, data, mesh, MeshAxes(data="data"), options=opts)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_surface():
    names = set(available_transports())
    assert {"simulated", "threaded", "multiprocess"} <= names
    for n in ("simulated", "threaded", "multiprocess"):
        spec = get_transport(n)
        assert spec.name == n
        assert callable(spec.factory)
    with pytest.raises(KeyError, match="unknown transport"):
        get_transport("carrier-pigeon")


def test_bad_transport_knobs_rejected(small_problem, one_device_mesh):
    with pytest.raises(ValueError, match="transport"):
        AsyncOptions(transport=7)
    with pytest.raises(ValueError, match="n_workers"):
        AsyncOptions(n_workers=0)
    with pytest.raises(ValueError, match="staleness_budget"):
        AsyncOptions(tau="auto", staleness_budget=-1.0)
    # a budget with a static tau would be silently ignored -> eager error
    with pytest.raises(ValueError, match="staleness_budget"):
        AsyncOptions(tau=2, staleness_budget=0.5)
    with pytest.raises(KeyError, match="unknown transport"):
        fit_async(
            DMTRLConfig(transport="smoke-signal"),
            small_problem.train,
            one_device_mesh,
            MeshAxes(data="data"),
        )
    # simulated derives workers from the mesh; a conflicting n_workers is an
    # error, not a silent override
    with pytest.raises(ValueError, match="n_workers"):
        fit_async(
            DMTRLConfig(n_workers=2),
            small_problem.train,
            one_device_mesh,
            MeshAxes(data="data"),
        )


# ---------------------------------------------------------------------------
# golden replay — simulated must stay bit-identical to the legacy engine
# ---------------------------------------------------------------------------
def _int_history(hist, keys):
    return {k: np.asarray(hist[k]).astype(int).tolist() for k in keys}


def test_golden_replay_one_device(golden, one_device_mesh):
    rec = golden["g1_tau2_omega1"]
    assert rec["devices"] == 1
    cfg_kw = dict(rec["config"])
    cfg_kw["async_delays"] = tuple(cfg_kw["async_delays"])
    sp = synthetic(1, **rec["problem"])
    _, _, _, hist = fit_async(
        DMTRLConfig(**cfg_kw), sp.train, one_device_mesh, MeshAxes(data="data")
    )
    assert _int_history(hist, rec["history"].keys()) == rec["history"]


_GOLDEN_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    import json, sys
    import jax, numpy as np
    sys.path.insert(0, {repo!r} + "/src")
    from repro.core import DMTRLConfig, MeshAxes
    from repro.core.async_dmtrl import fit_async
    from repro.data.synthetic import synthetic

    rec = json.loads({rec!r})
    cfg_kw = dict(rec["config"]); cfg_kw["async_delays"] = tuple(cfg_kw["async_delays"])
    sp = synthetic(1, **rec["problem"])
    mesh = jax.make_mesh(({devices},), ("data",))
    _, _, _, hist = fit_async(
        DMTRLConfig(**cfg_kw), sp.train, mesh, MeshAxes(data="data")
    )
    out = {{k: np.asarray(hist[k]).astype(int).tolist() for k in rec["history"]}}
    print("REPLAY" + json.dumps(out))
    """
)


@pytest.mark.slow
@pytest.mark.parametrize(
    "case", ["g4_straggler_tau1", "g4_straggler_tau4_omega2", "g4_straggler_tau_auto"]
)
def test_golden_replay_straggler_mesh(golden, case):
    """4-worker straggler schedules (incl. tau="auto") replay bit-exactly
    on a real 4-device mesh in a subprocess."""
    rec = golden[case]
    code = _GOLDEN_SUBPROC.format(
        devices=rec["devices"], repo=REPO, rec=json.dumps(rec)
    )
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=600,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("REPLAY")][-1]
    assert json.loads(line[len("REPLAY"):]) == rec["history"]


# ---------------------------------------------------------------------------
# cross-transport parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_workers", [1, 2, 4])
def test_threaded_tau0_matches_reference(
    small_problem, small_cfg, ref_result, n_workers
):
    """Round-boundary snapshot versioning makes the threaded server's tau=0
    iterates order-independent: any worker count matches the reference
    engine to float-association tolerance."""
    W, sigma, state, hist = _fit_transport(
        small_cfg, small_problem.train, "threaded", n_workers, tau=0
    )
    np.testing.assert_allclose(W, np.asarray(ref_result.W), atol=ATOL)
    np.testing.assert_allclose(sigma, np.asarray(ref_result.sigma), atol=ATOL)
    assert hist["w_lag"].max() == 0
    total = small_cfg.outer_iters * small_cfg.rounds * n_workers
    assert len(hist["w_worker"]) == total


def test_threaded_matches_simulated_at_tau0(
    small_problem, small_cfg, one_device_mesh
):
    """Transport-parity anchor (simulated vs threaded): same final (W,
    Sigma) to tolerance at tau=0."""
    W1, s1, _, h1 = fit_async(
        small_cfg, small_problem.train, one_device_mesh, MeshAxes(data="data")
    )
    W2, s2, _, h2 = _fit_transport(
        small_cfg, small_problem.train, "threaded", 4, tau=0
    )
    np.testing.assert_allclose(W1, W2, atol=ATOL)
    np.testing.assert_allclose(s1, s2, atol=ATOL)
    # both histories flow through the same receipt path
    for h in (h1, h2):
        s = cv.staleness_summary(h)
        assert s["n_commits"] == len(h["w_worker"])
        assert s["max_lag"] == 0.0


def test_threaded_ssp_gate_correct_under_stragglers(small_problem, small_cfg):
    """Genuinely nondeterministic thread arrivals, paced 4x straggler: the
    SSP gate must still bound lag by tau, staleness must actually occur,
    and the run must converge within 2x of the synchronous gap."""
    sync_gap = None
    for tau in (0, 1):
        W, sigma, state, hist = _fit_transport(
            small_cfg, small_problem.train, "threaded", 4,
            tau=tau, async_delays=(1, 1, 1, 4),
        )
        assert hist["w_lag"].max() <= tau
        if tau == 0:
            sync_gap = abs(float(hist["gap"][-1]))
        else:
            assert hist["w_staleness"].max() >= 1
            assert float(hist["gap"][-1]) <= 2.0 * sync_gap + 1e-9
        # dual blocks only move where tasks have real samples (no snapshot
        # row mixing across the concurrent commits)
        alpha = np.asarray(state.alpha)[: small_problem.train.m]
        mask = np.asarray(small_problem.train.mask)
        assert np.all(alpha[mask == 0.0] == 0.0)
        assert all(
            np.any(alpha[i][mask[i] == 1.0] != 0.0)
            for i in range(small_problem.train.m)
        )


def test_threaded_omega_overlap_installs(small_problem, small_cfg):
    """omega_delay > 0 on the host server: the deferred Sigma lands inside
    the next W-step (boundary refresh) — never dropped — and the run still
    converges to a valid trace-1 Sigma."""
    cfg = dataclasses.replace(small_cfg, outer_iters=3)
    W, sigma, _, hist = _fit_transport(
        cfg, small_problem.train, "threaded", 2,
        tau=1, omega_delay=2, async_delays=(1, 2),
    )
    assert np.trace(sigma) == pytest.approx(1.0, abs=1e-4)
    assert hist["gap"][-1] < hist["gap"][0]


def test_threaded_warm_start_partial_fit(small_problem):
    """partial_fit warm-starts the host server state (alpha/Sigma install)
    and history merging keeps the commit clock monotone."""
    est = DMTRLEstimator(
        engine="async",
        async_options=AsyncOptions(transport="threaded", n_workers=2),
        loss="hinge", lam=1e-3, outer_iters=1, rounds=3, local_iters=32,
        solver="block_gram", block_size=32, seed=0,
    )
    est.partial_fit(small_problem.train)
    gap0 = est.history["gap"][-1]
    n0 = len(est.history["round"])
    est.partial_fit(small_problem.train)
    assert len(est.history["round"]) == 2 * n0
    assert est.history["round"][n0] > est.history["round"][n0 - 1]
    assert est.history["gap"][-1] <= gap0 + 1e-6


def test_estimator_routes_transport_and_rejects_core_kwarg(small_problem):
    with pytest.raises(ValueError, match="per-engine options"):
        DMTRLEstimator(engine="async", transport="threaded")
    with pytest.raises(ValueError, match="per-engine options"):
        DMTRLEstimator(engine="reference", staleness_budget=1.0)
    est = DMTRLEstimator(
        engine="async",
        async_options=AsyncOptions(transport="threaded", n_workers=2),
        loss="hinge", lam=1e-3, outer_iters=1, rounds=2, local_iters=32,
        solver="block_gram", block_size=32, seed=0,
    ).fit(small_problem.train)
    assert est.score(small_problem.test) > 0.0
    assert len(est.history["w_worker"]) == 2 * 2  # rounds x workers


# ---------------------------------------------------------------------------
# protocol surface — a generic driver can run the simulated member too
# ---------------------------------------------------------------------------
def test_simulated_protocol_methods_drive_one_w_step(
    small_problem, one_device_mesh
):
    """gate/snapshot/commit on the simulated transport are real protocol
    methods: driving one W-step manually (one worker at a time) matches the
    reference engine on a fixed-Sigma regularizer."""
    import jax

    from repro.core.omega_regularizers import get_regularizer

    cfg = DMTRLConfig(
        loss="hinge", lam=1e-3, outer_iters=1, rounds=3, local_iters=32,
        solver="block_gram", block_size=32, seed=0,
        omega_regularizer="identity_stl",
    )
    data = small_problem.train
    reg = get_regularizer("identity_stl")
    t = get_transport("simulated").factory()
    t.setup(
        cfg, data, mesh=one_device_mesh, axes=MeshAxes(data="data"),
        reg=reg, init=None, track=False,
    )
    rho = 1.0  # identity_stl couples nothing; any rho-consistent value —
    # must match what the reference run uses below, so compute it there too
    from repro.core.dmtrl import _rho_value

    rho = _rho_value(cfg, t.rho_sigma(), reg=reg)
    solve = make_block_solver(cfg, t.data.n_max, rho)
    key = jax.random.PRNGKey(cfg.seed)
    _, outer_key = jax.random.split(key)
    round_keys = jax.random.split(outer_key, cfg.rounds)
    tids = np.arange(t.m, dtype=np.int32)
    for r in range(cfg.rounds):
        assert t.gate(0, r)
        snap = t.snapshot(0)
        dalpha, db = solve(
            t.data.x, t.data.y, snap.alpha_rows, snap.W_rows, t.data.n,
            snap.sigma_rows, tids, round_keys[r],
        )
        receipt = t.commit(0, r, (dalpha, db))
        assert receipt.worker == 0 and receipt.round == r
        assert receipt.staleness == 0 and receipt.lag == 0
        assert receipt.version == r + 1
    W, sigma, state, hist = t.result()
    ref = fit_reference(cfg, data, regularizer=reg)
    np.testing.assert_allclose(W, np.asarray(ref.W), atol=ATOL)
    assert cv.staleness_summary(hist)["n_commits"] == cfg.rounds


# ---------------------------------------------------------------------------
# degenerate tau=0 member: the synchronous engine's receipts
# ---------------------------------------------------------------------------
def test_sync_engine_receipts_flow_through_staleness_summary(
    small_problem, small_cfg, one_device_mesh
):
    from repro.core.distributed import fit_distributed

    _, _, _, hist = fit_distributed(
        small_cfg, small_problem.train, one_device_mesh, MeshAxes(data="data")
    )
    s = cv.staleness_summary(hist)
    total = small_cfg.outer_iters * small_cfg.rounds
    assert s["n_commits"] == total  # 1 worker x rounds
    assert s["max_staleness"] == 0.0 and s["max_lag"] == 0.0
    assert hist["tau_trace"].max() == 0
    # sync histories now carry the transport clock too
    ticks, gaps = cv.effective_gap_curve(hist)
    np.testing.assert_array_equal(ticks, np.arange(1, total + 1))


def test_sync_and_async_tau0_histories_agree(
    small_problem, small_cfg, one_device_mesh
):
    """The degenerate member really is the same event stream: identical
    integer bookkeeping between fit_distributed and simulated tau=0."""
    from repro.core.distributed import fit_distributed

    _, _, _, h_sync = fit_distributed(
        small_cfg, small_problem.train, one_device_mesh, MeshAxes(data="data")
    )
    _, _, _, h_async = fit_async(
        small_cfg, small_problem.train, one_device_mesh, MeshAxes(data="data")
    )
    for k in ("w_worker", "w_round", "w_staleness", "w_lag", "w_tick",
              "tau_trace"):
        np.testing.assert_array_equal(h_sync[k], h_async[k])


# ---------------------------------------------------------------------------
# cost-aware tau="auto" (staleness_budget)
# ---------------------------------------------------------------------------
def test_adapt_tau_budget_transitions():
    slack = {"max_lag": 0.0, "mean_staleness": 0.0}
    hot = {"max_lag": 3.0, "mean_staleness": 2.5}
    # budget exceeded -> narrow, even when the gate refused starts
    assert _adapt_tau(3, 5, hot, 8, staleness_budget=1.0) == 2
    # ... and clamps at the floor
    assert _adapt_tau(0, 5, hot, 8, staleness_budget=1.0) == 0
    # budget satisfied -> the refusal/widen rule still applies
    assert _adapt_tau(3, 2, slack, 8, staleness_budget=1.0) == 4
    assert _adapt_tau(8, 2, slack, 8, staleness_budget=1.0) == 8  # cap
    # budget satisfied, no refusals, unused slack -> narrow as before
    assert _adapt_tau(3, 0, slack, 8, staleness_budget=1.0) == 2
    # exactly at budget is NOT exceeded -> hold/widen path
    at_budget = {"max_lag": 3.0, "mean_staleness": 1.0}
    assert _adapt_tau(3, 0, at_budget, 8, staleness_budget=1.0) == 3
    # no budget -> legacy controller behaviour (regression guard)
    assert _adapt_tau(3, 0, {"max_lag": 3.0}, 8) == 3
    assert _adapt_tau(3, 0, {"max_lag": 0.0}, 8) == 2
    assert _adapt_tau(3, 1, {"max_lag": 3.0}, 8) == 4


def test_staleness_budget_zero_pins_tau_auto_at_zero(small_problem, small_cfg):
    """A zero budget means "never pay staleness": the controller must keep
    narrowing ahead of the widen rule, so tau stays 0 under a straggler
    that would otherwise widen the gate."""
    cfg = dataclasses.replace(small_cfg, outer_iters=2)
    _, _, _, hist = _fit_transport(
        cfg, small_problem.train, "threaded", 4,
        tau="auto", async_delays=(1, 1, 1, 4), staleness_budget=0.0,
    )
    assert hist["tau_trace"].max() == 0


def test_tau_auto_still_widens_without_budget(small_problem, small_cfg):
    """Same straggler schedule without a budget: the paced gate refusals
    must widen the bound (the controller's legacy behaviour)."""
    cfg = dataclasses.replace(small_cfg, outer_iters=2)
    _, _, _, hist = _fit_transport(
        cfg, small_problem.train, "threaded", 4,
        tau="auto", async_delays=(1, 1, 1, 4),
    )
    assert hist["tau_trace"][0] == 0
    assert hist["tau_trace"].max() >= 1
    assert hist["gate_refusals"][-1] >= 1


# ---------------------------------------------------------------------------
# deprecation hygiene
# ---------------------------------------------------------------------------
def _one_deprecation(fn, *args, **kwargs):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = fn(*args, **kwargs)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1, [str(w.message) for w in dep]
    assert "deprecated" in str(dep[0].message)
    return out


def test_deprecated_wrappers_warn_exactly_once(
    small_problem, small_cfg, one_device_mesh
):
    import repro.core as core

    ax = MeshAxes(data="data")
    # raw async_delays/tau kwargs on the legacy config still route through
    legacy = dataclasses.replace(small_cfg, tau=1, async_delays=(2,))
    _, _, _, hist = _one_deprecation(
        core.fit_async, legacy, small_problem.train, one_device_mesh, ax
    )
    assert hist["w_tick"][-1] == 2 * small_cfg.outer_iters * small_cfg.rounds
    _one_deprecation(
        core.fit_distributed, small_cfg, small_problem.train,
        one_device_mesh, ax,
    )
    _one_deprecation(core.fit, small_cfg, small_problem.train)


# ---------------------------------------------------------------------------
# multiprocess — socket/pickle parameter server (slow: per-worker processes
# each pay a jax import; wired into the slow CI job)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_multiprocess_tau0_matches_reference_and_threaded(
    small_problem, small_cfg, ref_result
):
    W, sigma, _, hist = _fit_transport(
        small_cfg, small_problem.train, "multiprocess", 2, tau=0
    )
    np.testing.assert_allclose(W, np.asarray(ref_result.W), atol=ATOL)
    np.testing.assert_allclose(sigma, np.asarray(ref_result.sigma), atol=ATOL)
    assert hist["w_lag"].max() == 0
    total = small_cfg.outer_iters * small_cfg.rounds * 2
    assert len(hist["w_worker"]) == total
    Wt, st_, _, _ = _fit_transport(
        small_cfg, small_problem.train, "threaded", 2, tau=0
    )
    np.testing.assert_allclose(W, Wt, atol=ATOL)
    np.testing.assert_allclose(sigma, st_, atol=ATOL)


@pytest.mark.slow
def test_multiprocess_ssp_straggler(small_problem, small_cfg):
    """Per-worker processes with a paced straggler at tau=1: gate-correct
    lag, real staleness, convergence within 2x of its own tau=0 run."""
    W0, _, _, h0 = _fit_transport(
        small_cfg, small_problem.train, "multiprocess", 2,
        tau=0, async_delays=(1, 4),
    )
    W1, _, _, h1 = _fit_transport(
        small_cfg, small_problem.train, "multiprocess", 2,
        tau=1, async_delays=(1, 4),
    )
    assert h1["w_lag"].max() <= 1
    assert float(h1["gap"][-1]) <= 2.0 * abs(float(h0["gap"][-1])) + 1e-9


# ---------------------------------------------------------------------------
# subscriber isolation — a raising callback must not unwind installs
# ---------------------------------------------------------------------------
def test_raising_subscriber_is_isolated_and_dropped(
    small_problem, small_cfg, caplog
):
    """Regression: a broken router subscriber used to propagate out of the
    Sigma-install path and kill the fit. Now it is logged + dropped and
    the install (and every other subscriber) proceeds."""
    import logging

    import jax.numpy as jnp

    from repro.core.omega_regularizers import resolve_regularizer

    cfg = dataclasses.replace(small_cfg, n_workers=1, transport="threaded")
    transport = get_transport("threaded").factory()
    reg = resolve_regularizer(cfg, None)
    transport.setup(
        cfg, small_problem.train, mesh=None, axes=None, reg=reg,
        init=None, track=False,
    )
    try:
        m = small_problem.train.m
        seen = []

        def broken_router(W, sigma, version):  # a raising subscriber tier
            raise RuntimeError("router exploded")

        transport.subscribe(broken_router)
        transport.subscribe(lambda W, s, v: seen.append(v))
        sig = jnp.asarray(np.eye(m, dtype=np.float32) / m)
        om = jnp.asarray(np.eye(m, dtype=np.float32) * m)
        with caplog.at_level(logging.ERROR, logger="repro.core.transport"):
            transport.install_sigma(sig, om, defer=False)  # must NOT raise
        assert seen == [1]  # the healthy subscriber still fired
        assert any("dropping it" in r.message for r in caplog.records)
        # the broken callback was dropped: the next install only reaches
        # the healthy subscriber and nothing is logged
        caplog.clear()
        transport.install_sigma(sig, om, defer=False)
        assert seen == [1, 2]
        assert not caplog.records
        assert not transport.unsubscribe(broken_router)  # already gone
    finally:
        transport.close()


def test_raising_subscriber_does_not_break_the_fit(small_problem, small_cfg):
    """End-to-end: a raising subscriber attached before fit_async leaves
    the result identical to an undisturbed run."""
    from repro.core import omega_regularizers as omega_reg
    from repro.core.dmtrl import _rho_value

    import jax

    opts = AsyncOptions(transport="threaded", n_workers=2, tau=0)
    cfg = opts.merge_into(small_cfg)
    reg = omega_reg.resolve_regularizer(cfg, None, m=small_problem.train.m)
    t = get_transport("threaded").factory()
    t.setup(
        cfg, small_problem.train, mesh=None, axes=MeshAxes(), reg=reg,
        init=None, track=True,
    )
    try:
        t.subscribe(lambda *a: (_ for _ in ()).throw(RuntimeError("boom")))
        key = jax.random.PRNGKey(cfg.seed)
        rho_sigma = t.rho_sigma()
        for p in range(cfg.outer_iters):
            rho = _rho_value(cfg, rho_sigma, n_blocks_scale=1.0, reg=reg)
            key, ok = jax.random.split(key)
            t.run_w_step(p, rho, ok)
            sig_t, om_t = reg.step(t.w_true(), cfg.omega_jitter)
            sig, om = t.pad_sigma(sig_t, om_t)
            t.install_sigma(sig, om, defer=False)
            rho_sigma = sig
        W, sigma, _, _ = t.result()
    finally:
        t.close()
    Wr, sr, _, _ = _fit_transport(
        small_cfg, small_problem.train, "threaded", 2, tau=0
    )
    np.testing.assert_allclose(W, Wr, atol=ATOL)


# ---------------------------------------------------------------------------
# wire codecs on the server transports (core/wire.py integration)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", ["bf16", "int8"])
def test_threaded_codec_objective_gap(
    small_problem, small_cfg, ref_result, codec
):
    """Lossy snapshot/commit codecs (with error feedback) keep the final
    objective within a small bounded gap of the exact run."""
    _, _, _, h_exact = _fit_transport(
        small_cfg, small_problem.train, "threaded", 2, tau=0
    )
    _, _, _, h_codec = _fit_transport(
        small_cfg, small_problem.train, "threaded", 2, tau=0, codec=codec
    )
    ref = abs(float(h_exact["primal"][-1]))
    gap = abs(float(h_codec["primal"][-1]) - float(h_exact["primal"][-1]))
    bound = {"bf16": 5e-3, "int8": 2e-2}[codec]
    assert gap <= bound * max(1.0, ref)


def test_payload_nbytes_codec_accounting(small_problem, small_cfg):
    """payload_nbytes: raw wire counts every field incl. alpha; codec wire
    counts the encoded (W, Sigma) only (alpha is worker-cached under a
    codec) and strictly shrinks none -> bf16 -> int8."""
    from repro.core.omega_regularizers import resolve_regularizer
    from repro.core.transport import payload_nbytes

    cfg = dataclasses.replace(small_cfg, n_workers=2, transport="threaded")
    t = get_transport("threaded").factory()
    t.setup(
        cfg, small_problem.train, mesh=None, axes=None,
        reg=resolve_regularizer(cfg, None), init=None, track=False,
    )
    try:
        snap = t.snapshot(0)
        raw = payload_nbytes(snap)
        assert raw == sum(
            np.asarray(a).nbytes
            for a in (snap.W_rows, snap.sigma_rows, snap.alpha_rows)
            if a is not None
        )
        sizes = {c: payload_nbytes(snap, c) for c in ("bf16", "int8")}
        assert raw > sizes["bf16"] > sizes["int8"]
    finally:
        t.close()


def test_threaded_wire_stats_alpha_elision(small_problem, small_cfg):
    """Under a lossy codec alpha ships exactly once per worker (then the
    worker-side mirror replays the server's eta*dalpha updates), so the
    aggregate compressed wire beats 4x on the fixture."""
    from repro.core import omega_regularizers as omega_reg
    from repro.core.dmtrl import _rho_value

    import jax

    opts = AsyncOptions(transport="threaded", n_workers=2, tau=0, codec="int8")
    cfg = opts.merge_into(small_cfg)
    reg = omega_reg.resolve_regularizer(cfg, None, m=small_problem.train.m)
    t = get_transport("threaded").factory()
    t.setup(
        cfg, small_problem.train, mesh=None, axes=MeshAxes(), reg=reg,
        init=None, track=False,
    )
    try:
        key = jax.random.PRNGKey(0)
        rho_sigma = t.rho_sigma()
        for p in range(cfg.outer_iters):
            rho = _rho_value(cfg, rho_sigma, n_blocks_scale=1.0, reg=reg)
            key, ok = jax.random.split(key)
            t.run_w_step(p, rho, ok)
            sig_t, om_t = reg.step(t.w_true(), cfg.omega_jitter)
            sig, om = t.pad_sigma(sig_t, om_t)
            t.install_sigma(sig, om, defer=False)
            rho_sigma = sig
        s = t.wire_stats
        assert s["codec"] == "int8"
        shipped = s["snapshot_bytes"] + s["commit_bytes"]
        raw = s["raw_snapshot_bytes"] + s["raw_commit_bytes"]
        assert raw / shipped >= 4.0
    finally:
        t.close()


# ---------------------------------------------------------------------------
# frame versioning — protocol skew fails loudly (core/wire.py)
# ---------------------------------------------------------------------------
def test_legacy_frame_raises_transport_protocol_error():
    """A legacy (unversioned) frame against the new receiver: the leading
    byte is the high byte of a 64-bit length (0x00), never a valid
    version, so the receiver diagnoses the skew instead of feeding pickle
    garbage."""
    import pickle
    import socket
    import struct

    from repro.core.transport import _recv_msg
    from repro.core.wire import TransportProtocolError

    a, b = socket.socketpair()
    try:
        payload = pickle.dumps(("hello", 0))
        a.sendall(struct.pack("!Q", len(payload)) + payload)  # OLD framing
        with pytest.raises(TransportProtocolError, match="legacy"):
            _recv_msg(b)
    finally:
        a.close()
        b.close()


def test_future_version_frame_raises_transport_protocol_error():
    import pickle
    import socket
    import struct

    from repro.core.transport import _recv_msg
    from repro.core.wire import WIRE_VERSION, TransportProtocolError

    a, b = socket.socketpair()
    try:
        payload = pickle.dumps(("hello", 0))
        a.sendall(
            struct.pack("!BQ", WIRE_VERSION + 3, len(payload)) + payload
        )
        with pytest.raises(TransportProtocolError, match="mismatch"):
            _recv_msg(b)
    finally:
        a.close()
        b.close()


def test_current_frame_roundtrips():
    import socket

    from repro.core.transport import _recv_msg, _send_msg

    a, b = socket.socketpair()
    try:
        _send_msg(a, ("commit", 3, [1, 2]))
        assert _recv_msg(b) == ("commit", 3, [1, 2])
    finally:
        a.close()
        b.close()


@pytest.mark.slow
def test_multiprocess_codec_matches_exact_run(small_problem, small_cfg):
    """The socket path with int8 + error feedback: worker-side alpha
    mirror + encoded frames stay within the codec gap bound of its own
    exact (codec='none') run."""
    W0, _, _, h0 = _fit_transport(
        small_cfg, small_problem.train, "multiprocess", 2, tau=0
    )
    W1, _, _, h1 = _fit_transport(
        small_cfg, small_problem.train, "multiprocess", 2, tau=0,
        codec="int8",
    )
    assert np.abs(W1 - W0).max() <= 5e-2
    gap = abs(float(h1["primal"][-1]) - float(h0["primal"][-1]))
    assert gap <= 2e-2 * max(1.0, abs(float(h0["primal"][-1])))
