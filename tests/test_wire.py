"""Wire-format layer (core/wire.py): codecs, error feedback, framing."""
import numpy as np
import pytest

from repro.core.wire import (
    WIRE_VERSION,
    Encoded,
    ErrorFeedback,
    TransportProtocolError,
    available_codecs,
    check_wire_version,
    get_codec,
    roundtrip,
)

SHAPES = [(3,), (16,), (256,), (257,), (300, 7), (1,), (8, 32)]


def _x(shape, seed=0):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_members():
    assert set(available_codecs()) >= {"none", "bf16", "int8"}


def test_unknown_codec_is_a_clear_error():
    with pytest.raises(KeyError, match="unknown wire codec"):
        get_codec("zstd")


# ---------------------------------------------------------------------------
# roundtrips + error bounds
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", SHAPES)
def test_none_codec_is_exact(shape):
    x = _x(shape)
    y = roundtrip(get_codec("none"), x)
    assert y.shape == x.shape and y.dtype == x.dtype
    np.testing.assert_array_equal(y, x)


@pytest.mark.parametrize("shape", SHAPES)
def test_bf16_roundtrip_error_bound(shape):
    x = _x(shape)
    y = roundtrip(get_codec("bf16"), x)
    assert y.shape == x.shape and y.dtype == x.dtype
    # bf16 keeps 8 mantissa bits: relative error <= 2^-8 after rounding
    np.testing.assert_allclose(y, x, rtol=2.0 ** -8, atol=0.0)


def test_bf16_matches_true_bfloat16_cast():
    # round-to-nearest-even at the mantissa boundary, checked against the
    # jax bfloat16 cast on values that straddle the tie
    import jax.numpy as jnp

    x = np.asarray(
        [1.0, 1.0 + 2.0 ** -8, 1.0 + 2.0 ** -9, -3.14159, 1e-30, 65504.0],
        np.float32,
    )
    got = roundtrip(get_codec("bf16"), x)
    want = np.asarray(jnp.asarray(x).astype(jnp.bfloat16).astype(jnp.float32))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("shape", SHAPES)
def test_int8_roundtrip_error_bound(shape):
    x = _x(shape)
    y = roundtrip(get_codec("int8"), x)
    assert y.shape == x.shape and y.dtype == x.dtype
    # per-block symmetric quantization: error <= half a step = scale / 2,
    # bounded globally by the worst block's scale
    step = np.max(np.abs(x)) / 127.0
    assert np.abs(y - x).max() <= step


def test_int8_zero_blocks_decode_exactly_to_zero():
    x = np.zeros((300,), np.float32)
    enc = get_codec("int8").encode(x)
    assert np.all(enc.scales == 0.0)
    np.testing.assert_array_equal(get_codec("int8").decode(enc), x)


def test_int8_pad_stays_off_the_wire():
    # a 16-element array must not pay for a whole 256 block
    enc = get_codec("int8").encode(np.ones((16,), np.float32))
    assert enc.data.size == 16
    assert enc.nbytes == 16 + 4  # codes + one f32 block scale


# ---------------------------------------------------------------------------
# nbytes ordering — the compression claim, per array
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(256,), (300, 7), (8, 32)])
def test_nbytes_strictly_decrease(shape):
    x = _x(shape)
    sizes = {
        name: get_codec(name).encode(x).nbytes
        for name in ("none", "bf16", "int8")
    }
    assert sizes["none"] > sizes["bf16"] > sizes["int8"]
    assert sizes["none"] == x.nbytes


def test_encoded_nbytes_counts_scales():
    enc = get_codec("int8").encode(_x((256,)))
    assert isinstance(enc, Encoded)
    assert enc.nbytes == enc.data.nbytes + enc.scales.nbytes


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------
def test_error_feedback_sum_tracks_true_sum():
    # a CONSTANT biased delta is the worst case for plain quantization:
    # the per-step bias accumulates linearly, while error feedback keeps
    # the accumulated error within one quantization step of the last
    # encode, independent of the number of steps
    codec = get_codec("int8")
    ef = ErrorFeedback(codec)
    d = (0.0013 * np.arange(1, 65, dtype=np.float32) / 64.0).astype(
        np.float32
    )
    steps = 50
    true_sum = steps * d.astype(np.float64)
    ef_sum = np.zeros_like(true_sum)
    plain_sum = np.zeros_like(true_sum)
    for _ in range(steps):
        ef_sum += codec.decode(ef.encode("k", d))
        plain_sum += codec.decode(codec.encode(d))
    err_ef = np.abs(ef_sum - true_sum).max()
    err_plain = np.abs(plain_sum - true_sum).max()
    one_step = np.abs(d).max() * 2.0 / 127.0  # generous per-encode bound
    assert err_ef <= one_step
    assert err_plain > 5 * err_ef  # the linear accumulation EF removes


def test_error_feedback_streams_are_independent():
    ef = ErrorFeedback(get_codec("int8"))
    a = np.full((8,), 0.3, np.float32)
    ef.encode("a", a)
    ra = ef._resid["a"].copy()
    ef.encode("b", -a)
    np.testing.assert_array_equal(ef._resid["a"], ra)  # untouched


def test_error_feedback_none_codec_is_stateless_passthrough():
    ef = ErrorFeedback(get_codec("none"))
    x = _x((16,))
    np.testing.assert_array_equal(ef.codec.decode(ef.encode("k", x)), x)
    assert not ef._resid


def test_error_feedback_reset():
    ef = ErrorFeedback(get_codec("int8"))
    ef.encode("a", _x((8,)))
    ef.encode("b", _x((8,)))
    ef.reset("a")
    assert "a" not in ef._resid and "b" in ef._resid
    ef.reset()
    assert not ef._resid


# ---------------------------------------------------------------------------
# frame versioning
# ---------------------------------------------------------------------------
def test_check_wire_version_accepts_current():
    check_wire_version(WIRE_VERSION)


def test_check_wire_version_rejects_legacy_framing():
    # a legacy unversioned frame leads with the high byte of a 64-bit
    # length — 0x00 for any sane message
    with pytest.raises(TransportProtocolError, match="legacy unversioned"):
        check_wire_version(0)


def test_check_wire_version_rejects_future_version():
    with pytest.raises(TransportProtocolError, match="version mismatch"):
        check_wire_version(WIRE_VERSION + 1)
